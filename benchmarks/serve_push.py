"""A/B benchmark: forward-push query backend vs the masked chunk
stepper on the loose-tolerance top-k personalized workload the push
route exists for (serve/push.py, DESIGN.md §11).

Both sides run through the SAME ``SlotScheduler`` — only ``route``
differs — so the comparison includes every serving cost (admission,
metrics, top-k extraction), not just the solver kernel.  Saturation
mode: the whole workload is offered at t=0 and the measured
queries/sec is the capacity of that route.

Rows per dataset and tolerance:

- ``serve_push/<ds>/push@<tol>``     — p50 latency (us) via the push
  route; derived carries qps / p99 / fallback count / mean sweeps.
- ``serve_push/<ds>/stepper@<tol>``  — the identical workload forced
  through the masked stepper; derived carries qps / p99 / speedup
  (push qps over stepper qps — the acceptance headline).

Standalone smoke mode (what CI runs after ``serve_load --smoke``):

    PYTHONPATH=src python -m benchmarks.serve_push --smoke \
        --json BENCH_serve.json

``--json`` MERGES into an existing BENCH_serve.json (serve_load.py
owns and overwrites that file, so this module must run second and
append its rows rather than clobber the load rows).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.serve import ServeMetrics, SlotScheduler
from repro.graphs import generators
from .common import Csv, Dataset, suite

TOLS = (1e-3, 1e-4)     # headline first; both stay in the push-
                        # eligible regime (tol >= push_tol = 1e-4)


def _onehot_workload(n: int, num_queries: int, *, seed: int):
    rng = np.random.default_rng(seed)
    nodes = rng.integers(0, n, size=num_queries)
    out = []
    for node in nodes:
        s = np.zeros(n, np.float32)
        s[node] = 1.0
        out.append(s)
    return out


def _drive(sch: SlotScheduler, workload, *, route: str, tol: float,
           top_k: int, max_iters: int) -> dict:
    """Saturation drain: offer everything at t=0, measure capacity."""
    for s in workload:
        sch.submit(s, top_k=top_k, tol=tol, max_iters=max_iters,
                   route=route)
    sch.run_until_drained()
    assert sch.trace_count <= 1, "scheduler retraced under load"
    s = sch.metrics.summary()
    assert s["error_count"] == 0
    assert s["converged_frac"] == 1.0
    return s


def run(datasets: list[Dataset], *, slots: int = 4,
        num_queries: int = 400, chunk: int = 4,
        part_size: int = 65536, top_k: int = 16, max_iters: int = 300,
        seed: int = 0) -> Csv:
    csv = Csv()
    for ds in datasets:
        workload = _onehot_workload(ds.n, num_queries, seed=seed)
        for tol in TOLS:
            stats = {}
            for route in ("push", "stepper"):
                sch = SlotScheduler(ds.graph, slots=slots,
                                    method="pcpm", part_size=part_size,
                                    chunk=chunk, metrics=ServeMetrics())
                # warm the route's compiled path off the clock
                sch.submit(workload[0], top_k=top_k, tol=tol,
                           max_iters=max_iters, route=route)
                sch.run_until_drained()
                sch.metrics = ServeMetrics()
                sch.metrics.clock = time.perf_counter
                stats[route] = _drive(sch, workload, route=route,
                                      tol=tol, top_k=top_k,
                                      max_iters=max_iters)
                if route == "push":
                    counters = stats[route]["counters"]
                    csv.add(
                        f"serve_push/{ds.name}/push@{tol:g}",
                        stats[route]["p50_ms"] / 1e3,
                        f"qps={stats[route]['qps']:.1f}"
                        f",p99_ms={stats[route]['p99_ms']:.2f}"
                        f",fallbacks={counters.get('push_fallbacks', 0)}"
                        f",mean_sweeps="
                        f"{stats[route]['mean_iterations']:.1f}"
                        f",n={stats[route]['count']}")
            speedup = stats["push"]["qps"] / stats["stepper"]["qps"]
            csv.add(
                f"serve_push/{ds.name}/stepper@{tol:g}",
                stats["stepper"]["p50_ms"] / 1e3,
                f"qps={stats['stepper']['qps']:.1f}"
                f",p99_ms={stats['stepper']['p99_ms']:.2f}"
                f",mean_iters={stats['stepper']['mean_iterations']:.1f}"
                f",push_speedup={speedup:.1f}x")
    return csv


def _merge_json(path: str, rows, meta: dict) -> None:
    """Append push rows into BENCH_serve.json without disturbing the
    serve_load rows it already holds (run serve_load first)."""
    doc = {}
    if os.path.exists(path) and os.path.getsize(path) > 0:
        try:
            with open(path) as f:
                doc = json.load(f)
        except json.JSONDecodeError:
            doc = {}
    kept = [r for r in doc.get("rows", [])
            if not r["name"].startswith("serve_push/")]
    doc["rows"] = kept + [{"name": n, "us_per_call": round(us, 1),
                           "derived": derived}
                          for n, us, derived in rows]
    doc["push_ab"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--num-queries", type=int, default=400)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one small RMAT graph, B=4")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="merge rows into an existing "
                         "BENCH_serve.json (append, not overwrite)")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.smoke:
        g = generators.rmat(10, 8, seed=1)
        datasets = [Dataset("rmat_smoke", g)]
        part_size = 64
        args.slots = 4
    else:
        datasets = suite(args.scale)[:2]
        from .common import default_part_size
        part_size = default_part_size(1 << args.scale)
    print("name,us_per_call,derived")
    out = run(datasets, slots=args.slots,
              num_queries=args.num_queries, chunk=args.chunk,
              part_size=part_size, top_k=args.top_k)
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(out.rows)} rows", flush=True)
    if args.json:
        _merge_json(args.json, out.rows, meta={
            "smoke": args.smoke, "slots": args.slots,
            "num_queries": args.num_queries, "chunk": args.chunk,
            "top_k": args.top_k, "tols": list(TOLS),
            "total_seconds": round(total_s, 1),
        })
        print(f"# merged into {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
