"""Paper Table IV + Fig 7: per-iteration runtime and GTEPS for
PDPR / BVGAS / PCPM, with the scatter/gather phase split.

The phase split uses the two-phase engine (bins round-trip through
memory, like the paper's bins round-trip through DRAM); the headline
per-iteration time uses the production fused engine — for PCPM that is
the blocked hierarchical gather (the same SpMV the fused PageRank
driver inlines into its `lax.while_loop`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spmv import SpMVEngine
from .common import Csv, Dataset, timeit


def _phase_times(eng: SpMVEngine, x) -> tuple[float, float]:
    """Per-phase timing over the backend's public ``phase_fns`` seam
    (the registry's two-phase contract, DESIGN.md §8)."""
    if eng.backend.phase_fns is None:
        return 0.0, 0.0
    scatter, gather = eng.backend.phase_fns(eng.plan)
    bins = scatter(x)
    return (timeit(lambda: jax.block_until_ready(scatter(x))),
            timeit(lambda: jax.block_until_ready(gather(bins))))


def run(datasets: list[Dataset], *, part_size: int = 65536,
        phases: bool = True) -> Csv:
    csv = Csv()
    for ds in datasets:
        x = jnp.asarray(
            np.random.default_rng(0).random(ds.n).astype(np.float32))
        for method in ("pdpr", "bvgas", "pcpm"):
            eng = SpMVEngine(ds.graph, method=method, part_size=part_size)
            t = timeit(lambda: jax.block_until_ready(eng(x)))
            gteps = ds.m / t / 1e9
            csv.add(f"table4/{ds.name}/{method}/iter", t,
                    f"GTEPS={gteps:.3f}")
            if phases and eng.backend.supports_two_phase:
                ts, tg = _phase_times(eng, x)
                csv.add(f"table4/{ds.name}/{method}/scatter", ts)
                csv.add(f"table4/{ds.name}/{method}/gather", tg)
            if method == "pcpm":
                csv.add(f"table4/{ds.name}/pcpm/r", 0.0,
                        f"r={eng.compression_ratio:.2f}")
    return csv
