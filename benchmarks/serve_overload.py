"""Overload benchmark for the resilience layer (DESIGN.md §10).

Two measured phases per dataset:

1. **Capacity probe** — the saturation mode of serve_load.py (whole
   workload offered at t=0, unbounded queue): the achieved queries/sec
   is the scheduler's capacity and becomes the saturation threshold
   the overload phase is calibrated against.
2. **Overload run** — an open-loop Poisson arrival stream at
   ``--overload``x the measured capacity (default 2x) against a
   scheduler with a bounded admission queue and a default deadline.
   The point of the resilience layer is that this run DOESN'T collapse:
   load past the bound is shed with explicit per-query rejections, and
   the queries that ARE admitted still meet the deadline.

Reported (and frozen as BENCH_overload.json by the CI reliability
job): capacity_qps, offered_qps, the admitted/rejected/expired/
degraded split, the max queue depth ever observed (must stay at the
configured bound), p99 latency of admitted queries, and whether that
p99 sat within the deadline.

    PYTHONPATH=src python -m benchmarks.serve_overload --smoke \
        --json BENCH_overload.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.reliability import ResilienceConfig
from repro.serve import ServeMetrics, SlotScheduler
from repro.graphs import generators
from .common import Dataset, suite
from .serve_load import _mixed_workload


def _measure_capacity(ds: Dataset, *, slots: int, chunk: int,
                      part_size: int, num_queries: int,
                      max_iters: int, seed: int) -> float:
    """Saturation probe: everything offered at t=0, measured qps is
    the capacity (the threshold serve_load.py now records)."""
    sch = SlotScheduler(ds.graph, slots=slots, method="pcpm",
                        part_size=part_size, chunk=chunk,
                        metrics=ServeMetrics())
    for seeds, top_k, tol in _mixed_workload(ds.n, num_queries,
                                             seed=seed):
        sch.submit(seeds, top_k=top_k, tol=tol, max_iters=max_iters)
    sch.run_until_drained()
    qps = sch.metrics.summary()["qps"]
    assert qps, "capacity probe served no queries"
    return float(qps)


def _overload_run(ds: Dataset, *, slots: int, chunk: int,
                  part_size: int, num_queries: int, max_iters: int,
                  offered_qps: float, max_queue: int,
                  deadline_s: float, seed: int) -> dict:
    """Open-loop Poisson arrivals at ``offered_qps`` against the
    bounded, deadline-aware scheduler; every query reaches an explicit
    terminal state (served / rejected / expired), none hang."""
    res = ResilienceConfig(max_queue=max_queue,
                           default_deadline_s=deadline_s)
    sch = SlotScheduler(ds.graph, slots=slots, method="pcpm",
                        part_size=part_size, chunk=chunk,
                        metrics=ServeMetrics(), resilience=res)
    workload = _mixed_workload(ds.n, num_queries, seed=seed)
    rng = np.random.default_rng(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps,
                                         num_queries))
    t0 = time.perf_counter()
    i = 0
    max_depth = 0
    while len(sch.completed) < num_queries:
        now = time.perf_counter() - t0
        while i < num_queries and arrivals[i] <= now:
            seeds, top_k, tol = workload[i]
            sch.submit(seeds, top_k=top_k, tol=tol,
                       max_iters=max_iters)
            i += 1
        max_depth = max(max_depth, sch.queued)
        if sch.queued or sch.active_slots:
            sch.step()
        elif i < num_queries:
            time.sleep(min(1e-3, arrivals[i] - now))
    assert sch.trace_count == 1, "scheduler retraced under overload"
    assert max_depth <= max_queue, "queue depth exceeded the bound"

    counters = sch.metrics.counters
    served = [r for r in sch.completed if r.error is None]
    p99_s = sch.metrics.percentile(99.0)
    return {
        "offered_qps": round(offered_qps, 1),
        "deadline_s": deadline_s,
        "max_queue": max_queue,
        "submitted": num_queries,
        "served": len(served),
        "rejected": int(counters.get("rejected", 0)),
        "expired": int(counters.get("expired", 0)),
        "degraded": int(counters.get("degraded", 0)),
        "deadline_hits": int(counters.get("deadline_hits", 0)),
        "max_queue_depth": max_depth,
        "p99_admitted_ms": (round(p99_s * 1e3, 1)
                            if p99_s is not None else None),
        "within_deadline": (p99_s is not None
                            and p99_s <= deadline_s),
    }


def run(datasets: list[Dataset], *, slots: int, chunk: int,
        part_size: int, num_queries: int, max_iters: int,
        overload: float, max_queue: int, deadline_s: float,
        seed: int = 0) -> list[dict]:
    out = []
    for ds in datasets:
        capacity = _measure_capacity(
            ds, slots=slots, chunk=chunk, part_size=part_size,
            num_queries=num_queries, max_iters=max_iters, seed=seed)
        row = _overload_run(
            ds, slots=slots, chunk=chunk, part_size=part_size,
            num_queries=num_queries, max_iters=max_iters,
            offered_qps=overload * capacity, max_queue=max_queue,
            deadline_s=deadline_s, seed=seed)
        row = {"name": ds.name, "n": ds.n, "m": ds.m,
               "capacity_qps": round(capacity, 1), **row}
        out.append(row)
        shed = row["rejected"] + row["expired"]
        print(f"{ds.name}: capacity={row['capacity_qps']:.0f} qps, "
              f"offered={row['offered_qps']:.0f} qps "
              f"({overload:g}x) -> served {row['served']}, "
              f"shed {shed} explicitly, depth<={row['max_queue_depth']}"
              f", p99={row['p99_admitted_ms']}ms "
              f"(within deadline: {row['within_deadline']})",
              flush=True)
        assert shed > 0, "overload run shed nothing at >=2x capacity"
        assert row["within_deadline"], \
            "p99 of admitted queries exceeded the deadline"
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--num-queries", type=int, default=80)
    ap.add_argument("--overload", type=float, default=2.0,
                    help="offered load as a multiple of measured "
                         "capacity (default 2x)")
    ap.add_argument("--max-queue", type=int, default=8)
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="per-query deadline in seconds")
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--max-iters", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one small RMAT graph, B=4")
    ap.add_argument("--json", type=str, default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.json:
        open(args.json, "a").close()

    t0 = time.time()
    if args.smoke:
        g = generators.rmat(10, 8, seed=1)
        datasets = [Dataset("rmat_smoke", g)]
        part_size = 64
        args.slots = 4
    else:
        datasets = suite(args.scale)[:2]
        from .common import default_part_size
        part_size = default_part_size(1 << args.scale)
    rows = run(datasets, slots=args.slots, chunk=args.chunk,
               part_size=part_size, num_queries=args.num_queries,
               max_iters=args.max_iters, overload=args.overload,
               max_queue=args.max_queue, deadline_s=args.deadline)
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(rows)} datasets", flush=True)
    if args.json:
        doc = {
            "smoke": args.smoke,
            "slots": args.slots,
            "num_queries": args.num_queries,
            "overload_factor": args.overload,
            "total_seconds": round(total_s, 1),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
