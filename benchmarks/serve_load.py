"""Open-loop load generator for the continuous-batching PageRank
query scheduler (serve/scheduler.py, DESIGN.md §7).

Arrivals are pre-sampled from a Poisson process at ``rate_qps`` and
replayed against the wall clock — open loop, so a slow server grows
its queue and the reported latency honestly includes queueing.
``rate_qps=None`` offers the whole workload at t=0 (saturation mode):
the measured queries/sec is then the scheduler's capacity.

The query mix mirrors a personalized-PageRank serving workload: mostly
single-seed personalized queries (mixed tolerances -> mixed
convergence times, the case continuous batching exists for), some
uniform-teleport queries, some top-k-only queries.

Reported per dataset:
- ``serve/<ds>/iter``    — seconds per (n, B) multi-vector iteration of
  the warm stepper with every slot active (the SpMV unit of work);
- ``serve/<ds>/load``    — p50 latency as us_per_call, with qps / p99 /
  mean iterations in the derived column.

Standalone smoke mode (what CI runs and freezes as BENCH_serve.json):

    PYTHONPATH=src python -m benchmarks.serve_load --smoke \
        --json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.serve import ServeMetrics, SlotScheduler
from repro.graphs import generators
from .common import Csv, Dataset, suite


def _mixed_workload(n: int, num_queries: int, *, seed: int):
    """(seeds, top_k, tol) tuples: ~60% personalized, 20% uniform,
    20% top-k, tolerances alternating between loose and tight."""
    rng = np.random.default_rng(seed)
    queries = []
    for i in range(num_queries):
        tol = (1e-3, 1e-5)[i % 2]
        kind = i % 5
        if kind < 3:
            seeds = np.zeros(n, np.float32)
            seeds[rng.integers(0, n, size=3)] = 1.0
            queries.append((seeds, None, tol))
        elif kind == 3:
            queries.append((None, None, tol))
        else:
            queries.append((None, min(100, n), tol))
    return queries


def _measure_iter_time(ds: Dataset, *, slots: int, chunk: int,
                       part_size: int, warm_iters: int = 32) -> float:
    """Warm steady-state seconds per multi-vector iteration: every slot
    active, fixed iteration budget, one timed drain."""
    sch = SlotScheduler(ds.graph, slots=slots, method="pcpm",
                        part_size=part_size, chunk=chunk)
    for _ in range(slots):            # warm-up drain (first dispatches)
        sch.submit(tol=0.0, max_iters=chunk)
    sch.run_until_drained()
    for _ in range(slots):
        sch.submit(tol=0.0, max_iters=warm_iters)
    t0 = time.perf_counter()
    sch.run_until_drained()
    return (time.perf_counter() - t0) / warm_iters


def run(datasets: list[Dataset], *, slots: int = 4,
        num_queries: int = 50, rate_qps: float | None = None,
        chunk: int = 4, part_size: int = 65536, max_iters: int = 100,
        seed: int = 0) -> Csv:
    csv = Csv()
    for ds in datasets:
        iter_s = _measure_iter_time(ds, slots=slots, chunk=chunk,
                                    part_size=part_size)
        csv.add(f"serve/{ds.name}/iter", iter_s,
                f"B={slots},chunk={chunk}")

        sch = SlotScheduler(ds.graph, slots=slots, method="pcpm",
                            part_size=part_size, chunk=chunk,
                            metrics=ServeMetrics())
        workload = _mixed_workload(ds.n, num_queries, seed=seed)
        rng = np.random.default_rng(seed + 1)
        if rate_qps is None:
            arrivals = np.zeros(num_queries)
        else:
            arrivals = np.cumsum(rng.exponential(1.0 / rate_qps,
                                                 num_queries))
        t0 = time.perf_counter()
        i = 0
        while len(sch.completed) < num_queries:
            now = time.perf_counter() - t0
            while i < num_queries and arrivals[i] <= now:
                seeds, top_k, tol = workload[i]
                sch.submit(seeds, top_k=top_k, tol=tol,
                           max_iters=max_iters)
                i += 1
            if sch.queued or sch.active_slots:
                sch.step()
            elif i < num_queries:
                time.sleep(min(1e-3, arrivals[i] - now))
        assert sch.trace_count == 1, "scheduler retraced under load"
        s = sch.metrics.summary()
        # honest load labeling: in saturation mode the achieved qps IS
        # the capacity threshold, so record it as such; in rate mode,
        # flag whether the server actually kept up with the offered
        # load (saturated = it could not) instead of leaving the
        # regime ambiguous
        if rate_qps is None:
            regime = f",mode=saturation,capacity_qps={s['qps']:.1f}"
        else:
            saturated = s["qps"] < 0.95 * rate_qps
            regime = f",rate={rate_qps:g},saturated={saturated}"
        csv.add(f"serve/{ds.name}/load", s["p50_ms"] / 1e3,
                f"qps={s['qps']:.1f},p99_ms={s['p99_ms']:.1f}"
                f",mean_iters={s['mean_iterations']:.1f}"
                f",n={s['count']}" + regime)
    return csv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--num-queries", type=int, default=50)
    ap.add_argument("--rate", type=float, default=None,
                    help="offered load in queries/sec "
                         "(default: saturation)")
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one small RMAT graph, B=4")
    ap.add_argument("--json", type=str, default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.json:
        open(args.json, "a").close()

    t0 = time.time()
    if args.smoke:
        g = generators.rmat(10, 8, seed=1)
        datasets = [Dataset("rmat_smoke", g)]
        part_size = 64
        args.slots = 4
    else:
        datasets = suite(args.scale)[:2]
        from .common import default_part_size
        part_size = default_part_size(1 << args.scale)
    print("name,us_per_call,derived")
    out = run(datasets, slots=args.slots, num_queries=args.num_queries,
              rate_qps=args.rate, chunk=args.chunk,
              part_size=part_size)
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(out.rows)} rows", flush=True)
    if args.json:
        doc = {
            "smoke": args.smoke,
            "slots": args.slots,
            "num_queries": args.num_queries,
            "rate_qps": args.rate,
            "chunk": args.chunk,
            "total_seconds": round(total_s, 1),
            "datasets": [{"name": d.name, "n": d.n, "m": d.m}
                         for d in datasets],
            "rows": [{"name": n, "us_per_call": round(us, 1),
                      "derived": derived}
                     for n, us, derived in out.rows],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
