"""A/B benchmark: async gateway vs synchronous scheduler on a mixed
push-eligible/stepper serving workload (repro/gateway, DESIGN.md §13).

Three saturation runs per dataset, identical workload:

- ``gateway/<ds>/sync``  — the PR 6 front door: one caller thread does
  admission, stepping AND inline push serving, at the session's static
  slot count.  This is the baseline the gateway must beat.
- ``gateway/<ds>/cold``  — ``Session.gateway()``: autotuned slot pool,
  dedicated device thread, push worker pool, empty warm-result cache.
  Derived carries the speedup over sync — the acceptance headline.
- ``gateway/<ds>/hot``   — the same workload resubmitted to the same
  gateway: every query repeats, so the warm-result LRU answers in O(k)
  without touching a solver.

Latency is measured CALLER-side (submit to future-done callback), so
gateway queue time counts against it — no hiding time in the backlog.

Workload: distinct one-hot seeds; half are top-k at ``tol=1e-3`` (the
push-eligible regime, served on the worker pool), half are FULL-VECTOR
personalized queries at serve_load's alternating loose/tight
tolerances — stepper-bound because they need the whole rank vector,
which push cannot deliver.

Standalone smoke mode (what CI runs after serve_load/serve_push):

    PYTHONPATH=src python -m benchmarks.serve_gateway --smoke \
        --json BENCH_serve.json

``--json`` MERGES into an existing BENCH_serve.json (serve_load.py
owns and overwrites that file; this module appends its rows).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

import repro
from repro.gateway import GatewayConfig
from repro.serve import ServeMetrics, SlotScheduler
from repro.graphs import generators
from .common import Csv, Dataset, suite

PUSH_TOL = 1e-3           # >= scheduler push_tol -> worker-pool route
STEP_TOLS = (1e-3, 1e-5)  # full-vector queries alternate loose/tight


def _workload(n: int, num_queries: int, *, top_k: int, seed: int):
    """(seeds, top_k, tol) tuples: distinct one-hot seeds (distinct
    cache keys — the cold run must not get accidental hits), odd
    indices push-eligible top-k, even indices full-vector
    (stepper-bound at any tolerance)."""
    rng = np.random.default_rng(seed)
    nodes = rng.choice(n, size=min(num_queries, n), replace=False)
    out = []
    for i, node in enumerate(nodes):
        s = np.zeros(n, np.float32)
        s[node] = 1.0
        out.append((s, top_k, PUSH_TOL) if i % 2 else
                   (s, None, STEP_TOLS[(i // 2) % 2]))
    return out


def _percentiles(lat: list) -> tuple[float, float]:
    a = np.asarray(lat)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _drive_sync(sch: SlotScheduler, workload, *,
                max_iters: int) -> dict:
    t0 = time.perf_counter()
    for s, k, tol in workload:
        sch.submit(s, top_k=k, tol=tol, max_iters=max_iters)
    sch.run_until_drained()
    wall = time.perf_counter() - t0
    res = sch.completed[-len(workload):]
    assert all(r.error is None and r.converged for r in res)
    p50, p99 = _percentiles([r.latency_s for r in res])
    return {"qps": len(workload) / wall, "p50_s": p50, "p99_s": p99}


def _drive_gateway(gw, workload, *, max_iters: int) -> dict:
    """Open-loop saturation through the async front door; per-query
    latency from submit() to the future's done callback."""
    lat = [None] * len(workload)
    results = [None] * len(workload)

    def cb(i, t_sub):
        def _done(fut):
            lat[i] = time.perf_counter() - t_sub
            results[i] = fut.result()
        return _done

    t0 = time.perf_counter()
    futs = []
    for i, (s, k, tol) in enumerate(workload):
        t_sub = time.perf_counter()
        f = gw.submit(s, top_k=k, tol=tol, max_iters=max_iters)
        f.add_done_callback(cb(i, t_sub))
        futs.append(f)
    for f in futs:
        f.result(timeout=600)
    wall = time.perf_counter() - t0
    assert all(r.error is None and r.converged for r in results)
    p50, p99 = _percentiles(lat)
    return {"qps": len(workload) / wall, "p50_s": p50, "p99_s": p99,
            "cached": sum(r.cached for r in results)}


def run(datasets: list[Dataset], *, slots: int = 4,
        num_queries: int = 400, chunk: int = 4,
        part_size: int = 65536, top_k: int = 16, max_iters: int = 400,
        target_chunk_s: float = 0.025, seed: int = 0) -> Csv:
    csv = Csv()
    for ds in datasets:
        workload = _workload(ds.n, num_queries, top_k=top_k, seed=seed)
        warm = workload[: 2]          # one per route, off the clock

        # -- A: synchronous scheduler, static slot count ------------
        sch = SlotScheduler(ds.graph, slots=slots, method="pcpm",
                            part_size=part_size, chunk=chunk,
                            metrics=ServeMetrics())
        for s, k, tol in warm:
            sch.submit(s, top_k=k, tol=tol, max_iters=max_iters)
        sch.run_until_drained()
        sync = _drive_sync(sch, workload, max_iters=max_iters)
        assert sch.trace_count == 1, "sync scheduler retraced"
        csv.add(f"gateway/{ds.name}/sync", sync["p50_s"],
                f"qps={sync['qps']:.1f},p99_ms={sync['p99_s']*1e3:.2f}"
                f",B={slots}")

        # -- B: gateway, autotuned pool, cold then hot cache --------
        sess = repro.open(ds.graph, repro.EngineConfig(
            method="pcpm", part_size=part_size, chunk=chunk,
            slots=slots))
        cfg = GatewayConfig(target_chunk_s=target_chunk_s,
                            push_workers=2)
        with sess.gateway(config=cfg) as gw:
            gsch = gw._schedulers["default"]
            for s, k, tol in warm:
                gw.submit(s, top_k=k, tol=tol, max_iters=max_iters,
                          use_cache=False).result(timeout=600)
            cold = _drive_gateway(gw, workload, max_iters=max_iters)
            hot = _drive_gateway(gw, workload, max_iters=max_iters)
            chosen = gw.autotune_report.chosen
            assert gsch.trace_count == 1, "gateway scheduler retraced"
        assert cold["cached"] == 0
        csv.add(
            f"gateway/{ds.name}/cold", cold["p50_s"],
            f"qps={cold['qps']:.1f},p99_ms={cold['p99_s']*1e3:.2f}"
            f",B={chosen},speedup_vs_sync="
            f"{cold['qps'] / sync['qps']:.1f}x")
        csv.add(
            f"gateway/{ds.name}/hot", hot["p50_s"],
            f"qps={hot['qps']:.1f},p99_ms={hot['p99_s']*1e3:.2f}"
            f",cache_hits={hot['cached']},hit_rate="
            f"{hot['cached'] / len(workload):.2f}")
    return csv


def _merge_json(path: str, rows, meta: dict) -> None:
    """Append gateway rows into BENCH_serve.json without disturbing
    the serve_load/serve_push rows it already holds."""
    doc = {}
    if os.path.exists(path) and os.path.getsize(path) > 0:
        try:
            with open(path) as f:
                doc = json.load(f)
        except json.JSONDecodeError:
            doc = {}
    kept = [r for r in doc.get("rows", [])
            if not r["name"].startswith("gateway/")]
    doc["rows"] = kept + [{"name": n, "us_per_call": round(us, 1),
                           "derived": derived}
                          for n, us, derived in rows]
    doc["gateway_ab"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--slots", type=int, default=4,
                    help="sync baseline pool size (gateway autotunes)")
    ap.add_argument("--num-queries", type=int, default=400)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--target-chunk-s", type=float, default=0.025)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one small RMAT graph")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="merge rows into an existing "
                         "BENCH_serve.json (append, not overwrite)")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.smoke:
        g = generators.rmat(10, 8, seed=1)
        datasets = [Dataset("rmat_smoke", g)]
        part_size = 64
    else:
        datasets = suite(args.scale)[:2]
        from .common import default_part_size
        part_size = default_part_size(1 << args.scale)
    print("name,us_per_call,derived")
    out = run(datasets, slots=args.slots,
              num_queries=args.num_queries, chunk=args.chunk,
              part_size=part_size, top_k=args.top_k,
              target_chunk_s=args.target_chunk_s)
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(out.rows)} rows", flush=True)
    if args.json:
        _merge_json(args.json, out.rows, meta={
            "smoke": args.smoke, "sync_slots": args.slots,
            "num_queries": args.num_queries, "chunk": args.chunk,
            "top_k": args.top_k,
            "target_chunk_s": args.target_chunk_s,
            "push_tol": PUSH_TOL, "step_tols": list(STEP_TOLS),
            "total_seconds": round(total_s, 1),
        })
        print(f"# merged into {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
