"""Roofline analysis (deliverable g): turn dry-run JSONL records into
the per-(arch x shape x mesh) roofline table.

    PYTHONPATH=src python -m benchmarks.roofline \
        experiments/dryrun_single.jsonl [--md]

Terms (TPU v5e, per chip):  compute = FLOPs / 197e12,
memory = bytes / 819e9, collective = wire_bytes / 50e9.
FLOPs/bytes come from the depth-extrapolated unrolled cost passes
(per-device); wire bytes from the collective census of the compiled
module (ring-algorithm model).  ``fraction`` = compute / max(all three)
— the share of peak the dominant resource would allow.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK


def load(paths: list[str]) -> dict:
    """Latest record per (arch, shape, mesh) wins (reruns append);
    error records never shadow a good record."""
    recs = {}
    for p in paths:
        with open(p) as f:
            for line in f:
                r = json.loads(line)
                key = (r["arch"], r["shape"], r["mesh"])
                if "error" in r and key in recs \
                        and "error" not in recs[key]:
                    continue
                recs[key] = r
    return recs


def terms(rec: dict) -> dict | None:
    e = rec.get("extrapolated")
    if not e:
        return None
    t_c = e["flops"] / PEAK_FLOPS_BF16
    t_m = e["bytes"] / HBM_BW
    t_x = e["collective_bytes"] / ICI_BW_PER_LINK
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = rec.get("model_flops", 0.0) / rec.get("devices", 1)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0], "step_s": dom[1],
        "roofline_fraction": t_c / dom[1] if dom[1] else 0.0,
        "useful_flops_ratio": (mf / e["flops"]) if e["flops"] else 0.0,
        "hbm_fraction": rec.get("compile", {}).get("memory", {})
                           .get("hbm_fraction", float("nan")),
    }


NOTES = {
    "compute": "compute-bound: raise MXU utilization (fusion/layout)",
    "memory": "memory-bound: cut HBM traffic (kernel fusion, bf16, "
              "keep scores/messages in VMEM)",
    "collective": "collective-bound: shrink wire bytes (PCPM dedup, "
                  "overlap, int8 grads)",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args(argv)

    recs = load(args.paths)
    rows = []
    for (arch, shape, mesh), rec in sorted(recs.items()):
        if "skip" in rec:
            rows.append((arch, shape, mesh, None, rec["skip"]))
            continue
        if "error" in rec:
            rows.append((arch, shape, mesh, None, "ERROR"))
            continue
        t = terms(rec)
        if t is None:       # compile-only record (multi-pod pass)
            hbm = rec.get("compile", {}).get("memory", {}) \
                     .get("hbm_fraction", float("nan"))
            rows.append((arch, shape, mesh, None,
                         f"compile-only; HBM={hbm * 100:.0f}%"))
            continue
        rows.append((arch, shape, mesh, t, None))

    if args.md:
        print("| arch | shape | compute s | memory s | coll s | "
              "dominant | roofline frac | useful FLOPs | HBM | note |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    else:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,"
              "dominant,roofline_fraction,useful_flops_ratio,"
              "hbm_fraction")
    for arch, shape, mesh, t, skip in rows:
        if t is None:
            if args.md:
                print(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                      f"| {skip} |")
            else:
                print(f"{arch},{shape},{mesh},,,,SKIP({skip}),,,")
            continue
        if args.md:
            print(f"| {arch} | {shape} | {t['compute_s']:.3f} | "
                  f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                  f"{t['dominant']} | {t['roofline_fraction']:.2f} | "
                  f"{t['useful_flops_ratio']:.2f} | "
                  f"{t['hbm_fraction'] * 100:.0f}% | "
                  f"{NOTES[t['dominant']]} |")
        else:
            print(f"{arch},{shape},{mesh},{t['compute_s']:.4f},"
                  f"{t['memory_s']:.4f},{t['collective_s']:.4f},"
                  f"{t['dominant']},{t['roofline_fraction']:.3f},"
                  f"{t['useful_flops_ratio']:.3f},"
                  f"{t['hbm_fraction']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
