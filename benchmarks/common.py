"""Shared benchmark utilities: dataset suite, timers, CSV emission.

The paper evaluates on 6 large graphs (gplus/pld/web/kron/twitter/sd1).
This container is a 1-core CPU box, so the suite mirrors each graph's
*regime* at a scale that runs in minutes; --scale moves all of them up
or down together.  Regime mapping:

  kron     -> rmat, edge factor 31        (dense, skewed — paper's kron)
  social   -> rmat, edge factor 16        (twitter/gplus regime)
  plaw     -> Chung-Lu power law, deg 14  (pld/sd1 hyperlink regime)
  uniform  -> uniform random, deg 16      (worst-case locality)
  grid     -> 2D grid, row-major labels   (web regime: high locality)

Absolute GTEPS on this box is NOT the paper's Xeon GTEPS; the claims we
validate are the *relative* ones (PCPM vs BVGAS vs PDPR, r vs locality,
partition-size trends).  TPU-scale performance lives in the dry-run
roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.graphs import generators
from repro.graphs.formats import Graph


@dataclasses.dataclass
class Dataset:
    name: str
    graph: Graph

    @property
    def n(self):
        return self.graph.num_nodes

    @property
    def m(self):
        return self.graph.num_edges


def suite(scale: int = 16) -> list[Dataset]:
    side = int(np.sqrt(1 << scale))
    return [
        Dataset("kron", generators.rmat(scale, 31, seed=1)),
        Dataset("social", generators.rmat(scale, 16, seed=2)),
        Dataset("plaw", generators.power_law(1 << scale, 14, seed=3)),
        Dataset("uniform",
                generators.uniform_random(1 << scale, (1 << scale) * 16,
                                          seed=4)),
        Dataset("grid", generators.grid_2d(side, side)),
    ]


def default_part_size(n: int, *, k_target: int = 64) -> int:
    """Partition size giving ~k_target partitions.

    The paper uses 64K-node partitions on 30-100M-node graphs (k~512);
    at bench scale the REGIME to preserve is k >> 1 with degree/k in the
    paper's range — k=64 lands kron's r at 3.1 (paper: 3.06) and the
    reordered r at 7.0 (paper GOrder: 6.17).
    """
    return max(256, n // k_target)


def timeit(fn: Callable, *, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call (fn must block on completion)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


class Csv:
    """Collects ``name,us_per_call,derived`` rows and prints them."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, seconds: float = 0.0, derived: str = ""):
        self.rows.append((name, seconds * 1e6, derived))
        print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)

    def extend(self, other: "Csv"):
        self.rows.extend(other.rows)
