"""End-to-end PageRank (paper §VI headline): 20 iterations, all three
engines, correctness cross-check + total wall time including
pre-processing (the paper's amortization argument, §VI-D3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.pagerank import pagerank
from repro.core.spmv import SpMVEngine
from .common import Csv, Dataset


def run(datasets: list[Dataset], *, part_size: int = 65536,
        iters: int = 20) -> Csv:
    csv = Csv()
    for ds in datasets:
        ranks = {}
        for method in ("pdpr", "bvgas", "pcpm"):
            t0 = time.perf_counter()
            eng = SpMVEngine(ds.graph, method=method, part_size=part_size)
            t_pre = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = pagerank(ds.graph, engine=eng, num_iterations=iters)
            res.ranks.block_until_ready()
            t_iter = time.perf_counter() - t0
            ranks[method] = np.asarray(res.ranks)
            csv.add(f"e2e/{ds.name}/{method}", t_iter + t_pre,
                    f"pre_ms={t_pre * 1e3:.0f}"
                    f",periter_ms={t_iter / iters * 1e3:.1f}"
                    f",residual={res.residuals[-1]:.2e}")
        for m in ("bvgas", "pcpm"):
            err = float(np.abs(ranks[m] - ranks["pdpr"]).max())
            csv.add(f"e2e/{ds.name}/agree/{m}", 0.0, f"max_abs={err:.2e}")
    return csv
