"""End-to-end PageRank (paper §VI headline): 20 iterations, all three
engines, correctness cross-check + total wall time including
pre-processing (the paper's amortization argument, §VI-D3).

Uses the fused `lax.while_loop` driver (core/pagerank.py): the whole
iteration loop is one device dispatch with zero host transfers inside
it, so ``periter_ms`` of the first run includes the one-off trace +
compile, and the ``warm`` row shows the steady-state loop (what a
serving deployment pays after AOT compilation).

A fixed-size ``pcpm_pallas`` smoke runs at the end regardless of
--scale: off-TPU the kernel executes in the Pallas interpreter (a
Python-level grid loop, linear in edge blocks), so it gets a small
dedicated graph rather than riding the main datasets.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.backends import spmv_fn
from repro.core.pagerank import pagerank
from repro.core.plan import PlanConfig, build_plan, evict_plans
from repro.core.spmv import SpMVEngine
from repro.graphs import generators
from .common import Csv, Dataset


def _upload_plan(plan) -> None:
    """Build the plan's spmv closure and BLOCK on the issued device
    uploads, so the plan-timing window owns the full one-time cost on
    asynchronous backends too."""
    spmv_fn(plan)
    for leaf in jax.tree_util.tree_leaves(list(plan._device.values())):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _pallas_smoke(csv: Csv, *, iters: int = 10) -> None:
    g = generators.rmat(11, 8, seed=1)
    evict_plans(g)          # content-addressed: a rerun would cache-hit
    t0 = time.perf_counter()
    plan = build_plan(g, PlanConfig(method="pcpm_pallas",
                                    part_size=256))
    _upload_plan(plan)                   # pack + device upload
    eng = SpMVEngine(g, plan=plan)
    t_pre = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = pagerank(g, engine=eng, num_iterations=iters)
    res.ranks.block_until_ready()
    t_iter = time.perf_counter() - t0
    ref = pagerank(g, method="pdpr", num_iterations=iters)
    err = float(np.abs(np.asarray(res.ranks)
                       - np.asarray(ref.ranks)).max())
    csv.add("e2e/pallas_smoke/pcpm_pallas/plan", t_pre,
            f"r={plan.compression_ratio:.2f}")
    csv.add("e2e/pallas_smoke/pcpm_pallas/iterate", t_iter,
            f"periter_ms={t_iter / iters * 1e3:.1f}")
    csv.add("e2e/pallas_smoke/pcpm_pallas", t_iter + t_pre,
            f"n={g.num_nodes},m={g.num_edges}"
            f",periter_ms={t_iter / iters * 1e3:.1f}"
            f",vs_pdpr_max_abs={err:.2e}")


def run(datasets: list[Dataset], *, part_size: int = 65536,
        iters: int = 20) -> Csv:
    csv = Csv()
    for ds in datasets:
        ranks = {}
        methods = ["pdpr", "bvgas", "pcpm"]
        # earlier jobs (table4 etc.) may have populated the process
        # plan cache for these (graph, config) pairs — evict so the
        # plan-build rows time a genuine cold build, not a dict hit
        evict_plans(ds.graph)
        for method in methods:
            # plan-build vs iterate split (the paper's amortization
            # argument, §VI-D3): the plan is built once per (graph,
            # config) and every subsequent engine hits the cache.  The
            # device upload of the plan's streams (spmv_fn) is one-time
            # work too, so it belongs in the plan window, not iterate.
            t0 = time.perf_counter()
            plan = build_plan(ds.graph, PlanConfig(method=method,
                                                   part_size=part_size))
            _upload_plan(plan)
            t_plan = time.perf_counter() - t0
            eng = SpMVEngine(ds.graph, plan=plan)
            t0 = time.perf_counter()
            res = pagerank(ds.graph, engine=eng, num_iterations=iters)
            res.ranks.block_until_ready()
            t_iter = time.perf_counter() - t0
            ranks[method] = np.asarray(res.ranks)
            csv.add(f"e2e/{ds.name}/{method}/plan", t_plan,
                    f"r={plan.compression_ratio:.2f}")
            csv.add(f"e2e/{ds.name}/{method}/iterate", t_iter,
                    f"periter_ms={t_iter / iters * 1e3:.1f}")
            csv.add(f"e2e/{ds.name}/{method}", t_iter + t_plan,
                    f"pre_ms={t_plan * 1e3:.0f}"
                    f",periter_ms={t_iter / iters * 1e3:.1f}"
                    f",residual={res.residuals[-1]:.2e}")
            # steady state: loop already traced+compiled, one dispatch
            t0 = time.perf_counter()
            res = pagerank(ds.graph, engine=eng, num_iterations=iters)
            res.ranks.block_until_ready()
            t_warm = time.perf_counter() - t0
            csv.add(f"e2e/{ds.name}/{method}/warm", t_warm,
                    f"periter_ms={t_warm / iters * 1e3:.1f}")
        for m in methods[1:]:
            err = float(np.abs(ranks[m] - ranks["pdpr"]).max())
            csv.add(f"e2e/{ds.name}/agree/{m}", 0.0, f"max_abs={err:.2e}")
    _pallas_smoke(csv)
    return csv
