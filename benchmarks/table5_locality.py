"""Paper Table V + §VI-D1: node labeling vs compression ratio r.

GOrder is approximated by our hybrid (degree-bucketed BFS) ordering —
the paper's finding to reproduce is the DIRECTION: locality-optimized
labels raise r, and PCPM converts that into fewer bytes while BVGAS is
oblivious (validated in table6).
"""
from __future__ import annotations

from repro.core.partition import Partitioning
from repro.core.png import build_png
from repro.graphs import reorder
from .common import Csv, Dataset, timeit


ORDERINGS = {
    "orig": None,
    "degree": reorder.degree_order,
    "hybrid": reorder.hybrid_order,
}


def run(datasets: list[Dataset], *, part_size: int = 65536) -> Csv:
    csv = Csv()
    for ds in datasets:
        part = Partitioning(ds.n, part_size)
        for name, fn in ORDERINGS.items():
            g = ds.graph if fn is None else ds.graph.relabel(fn(ds.graph))
            layout = build_png(g, part)
            csv.add(f"table5/{ds.name}/{name}", 0.0,
                    f"r={layout.compression_ratio:.2f}"
                    f",E'={layout.num_updates}")
    return csv
