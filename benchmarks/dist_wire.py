"""Beyond-paper table: the paper's §VII distributed generalization.

When vertices are sharded over devices, PCPM's dedup means one update
per (vertex, destination shard) on the wire instead of one per
cross-shard edge (the edge-cut / distributed-BVGAS baseline).  This
benchmark reports the wire-byte reduction per dataset for the 8-shard
layout used in the distributed tests, plus its padded all-to-all cost
(the static-shape price XLA extracts).

Pure layout accounting — no devices needed.
"""
from __future__ import annotations

from repro.core.distributed import build_sharded_png
from .common import Csv, Dataset, timeit


def run(datasets: list[Dataset], *, num_shards: int = 8) -> Csv:
    csv = Csv()
    for ds in datasets:
        t = timeit(lambda: build_sharded_png(ds.graph, num_shards),
                   warmup=0, iters=1)
        layout = build_sharded_png(ds.graph, num_shards)
        d_v = 4
        pcpm_wire = layout.wire_updates * d_v
        edgecut_wire = layout.wire_edges * 2 * d_v  # value + dst id
        padded = (layout.num_shards ** 2 * layout.send_ids.shape[2]
                  * d_v)
        csv.add(f"dist/{ds.name}/wire", t,
                f"r_wire={layout.wire_compression:.2f}"
                f",pcpmMB={pcpm_wire / 1e6:.1f}"
                f",edgecutMB={edgecut_wire / 1e6:.1f}"
                f",paddedMB={padded / 1e6:.1f}")
    return csv
