"""Beyond-paper table: the sharded fused PageRank loop (DESIGN.md §6).

Per dataset, reports the single-device fused-loop baseline against the
``num_shards``-way sharded fused loop (all-to-all scatter + blocked
local gather + psum residual, one donated `lax.while_loop` dispatch),
plus the wire stats of the sharded layout.  ``us_per_call`` is
per-iteration time (total loop time / iterations), so the two rows are
directly comparable.

On a single host this measures the SPMD overhead floor (forced host
devices share the one CPU); on a real mesh the same program measures
interconnect scaling.  Shard count is clamped to the visible device
count — run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
to get N shards on CPU.
"""
from __future__ import annotations

import numpy as np

from .common import Csv, Dataset, timeit


def run(datasets: list[Dataset], *, num_shards: int = 8,
        part_size: int = 65536, num_iterations: int = 10) -> Csv:
    import jax
    from repro.core import SpMVEngine, pagerank

    avail = jax.device_count()
    shards = min(num_shards, avail)
    if shards < num_shards:
        print(f"# sharded: clamped {num_shards} -> {shards} shards "
              f"({avail} devices visible)", flush=True)

    csv = Csv()
    for ds in datasets:
        g = ds.graph
        eng_1 = SpMVEngine(g, method="pcpm", part_size=part_size)
        t1 = timeit(lambda: np.asarray(pagerank(
            g, engine=eng_1, num_iterations=num_iterations).ranks),
            warmup=1, iters=3)
        csv.add(f"sharded/{ds.name}/fused_1dev", t1 / num_iterations,
                f"iters={num_iterations}")

        eng_s = SpMVEngine(g, method="pcpm_sharded", num_shards=shards)
        layout = eng_s.sharded_layout
        ts = timeit(lambda: np.asarray(pagerank(
            g, engine=eng_s, num_iterations=num_iterations).ranks),
            warmup=1, iters=3)
        d_v = 4
        csv.add(f"sharded/{ds.name}/fused_{shards}dev",
                ts / num_iterations,
                f"r_wire={layout.wire_compression:.2f}"
                f",pcpmMB={layout.wire_updates * d_v / 1e6:.1f}"
                f",edgecutMB={layout.wire_edges * 2 * d_v / 1e6:.1f}"
                f",vs1dev={t1 / max(ts, 1e-12):.2f}x")
    return csv
