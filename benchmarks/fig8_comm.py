"""Paper Fig 8 + §V analytic model: memory traffic per edge.

Two byte counters per (dataset, method):

  model  — the paper's own communication model (eqs. 3-5) instantiated
           with the MEASURED compression ratio r of our PNG build.
           PDPR is reported at both its c_mr bounds (best/worst).
  hlo    — "bytes accessed" of the engine's compiled-for-CPU HLO module
           (cost_analysis), the JAX-native analogue of the paper's DRAM
           counters.  Absolute values include XLA bookkeeping; the
           *ordering* across methods is the validated claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_model import (ModelParams, pdpr_bytes, bvgas_bytes,
                                   pcpm_bytes)
from repro.core.spmv import SpMVEngine
from .common import Csv, Dataset


def _hlo_bytes(eng: SpMVEngine, x) -> float:
    if eng.method == "pdpr":
        fn = lambda xx: eng(xx)
    elif eng.method == "bvgas":
        fn = lambda xx: eng(xx)
    else:
        fn = lambda xx: eng(xx)
    ca = jax.jit(fn).lower(jax.ShapeDtypeStruct(x.shape, x.dtype)) \
        .compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))


def run(datasets: list[Dataset], *, part_size: int = 65536) -> Csv:
    csv = Csv()
    for ds in datasets:
        x = jnp.asarray(
            np.random.default_rng(0).random(ds.n).astype(np.float32))
        pcpm_eng = SpMVEngine(ds.graph, method="pcpm",
                              part_size=part_size)
        r = pcpm_eng.compression_ratio
        k = pcpm_eng.partitioning.num_partitions
        pm_hi = ModelParams(ds.n, ds.m, k, r, c_mr=1.0)
        pm_lo = ModelParams(ds.n, ds.m, k, r,
                            c_mr=min(1.0, ds.n * 4 / (ds.m * 64)))
        csv.add(f"fig8/{ds.name}/model/pdpr_worst", 0.0,
                f"B/edge={pdpr_bytes(pm_hi) / ds.m:.2f}")
        csv.add(f"fig8/{ds.name}/model/pdpr_best", 0.0,
                f"B/edge={pdpr_bytes(pm_lo) / ds.m:.2f}")
        csv.add(f"fig8/{ds.name}/model/bvgas", 0.0,
                f"B/edge={bvgas_bytes(pm_hi) / ds.m:.2f}")
        csv.add(f"fig8/{ds.name}/model/pcpm", 0.0,
                f"B/edge={pcpm_bytes(pm_hi) / ds.m:.2f},r={r:.2f}")
        for method in ("pdpr", "bvgas", "pcpm"):
            eng = (pcpm_eng if method == "pcpm" else
                   SpMVEngine(ds.graph, method=method,
                              part_size=part_size))
            b = _hlo_bytes(eng, x)
            csv.add(f"fig8/{ds.name}/hlo/{method}", 0.0,
                    f"B/edge={b / ds.m:.2f}")
    return csv
