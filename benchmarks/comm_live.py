"""Measured-vs-model communication accounting rows (DESIGN.md §14).

Where fig8_comm.py reports the paper's ANALYTIC byte model (eqs. 3-5)
and the compiled HLO's "bytes accessed", this job reports the third
surface the obs layer adds: bytes counted off the plan's REAL array
geometry — the padded streams the backends actually bind — accumulated
per executed pass by ``obs.comm.CommAccountant``.

Three rows per (dataset, method):

  comm/<ds>/<m>/measured — DRAM-model bytes/iteration off the plan
                           geometry (padding included, on-chip bins
                           traffic reported in ``derived`` separately)
  comm/<ds>/<m>/model    — the paper's eq. 3-5 prediction at the
                           plan's measured r, plus measured/model ratio
  comm/<ds>/<m>/live     — a real observed solve (Session with
                           ``observe=True``): executed passes counted
                           by the scheduler hook, accumulated bytes,
                           and the accountant's own ratio_vs_model —
                           proving the serving-path counters and the
                           static measurement agree

The pcpm measured/model ratio is the PR's acceptance bound (within 2x
at scale 16); the gap's composition — schedule padding, the bins write
+ read round trip eq. 5 folds into 1/r terms — is quantified in
DESIGN.md §14.
"""
from __future__ import annotations

import repro
from repro.core.plan import PlanConfig, build_plan
from repro.obs import vs_model

from .common import Csv, Dataset

METHODS = ("pcpm", "pdpr", "bvgas")


def run(datasets: list[Dataset], *, part_size: int = 65536,
        iters: int = 10) -> Csv:
    csv = Csv()
    for ds in datasets:
        for method in METHODS:
            plan = build_plan(ds.graph, PlanConfig(method=method,
                                                   part_size=part_size))
            cmp_ = vs_model(plan)
            csv.add(f"comm/{ds.name}/{method}/measured", 0.0,
                    f"B/iter={cmp_['measured_bytes_per_iter']:.0f},"
                    f"B/edge={cmp_['measured_bytes_per_iter'] / ds.m:.2f},"
                    f"onchip={cmp_['measured_onchip_bytes']:.0f}")
            derived = (f"B/iter={cmp_['model_bytes_per_iter']:.0f},"
                       f"ratio={cmp_['ratio']:.2f},r={cmp_['r']:.2f}")
            if "model_bytes_per_iter_best" in cmp_:
                derived += (f",best={cmp_['model_bytes_per_iter_best']:.0f}")
            csv.add(f"comm/{ds.name}/{method}/model", 0.0, derived)

            # live: the scheduler/solver hook path, not a recount
            sess = repro.open(ds.graph, repro.EngineConfig(
                method=method, part_size=part_size,
                num_iterations=iters, observe=True))
            sess.pagerank()
            summ = sess.obs.comm.summary().get(method)
            if summ:
                csv.add(f"comm/{ds.name}/{method}/live", 0.0,
                        f"passes={summ['passes']},"
                        f"bytes={summ['dram_bytes']:.0f},"
                        f"ratio={summ.get('ratio_vs_model', 0):.2f}")
            sess.obs.close()
    return csv


def summarize(rows) -> dict:
    """Fold comm/ rows into the JSON summary block: per dataset, per
    method, measured vs model bytes/iteration and their ratio."""
    summ: dict = {}

    def _field(derived, key, cast=float):
        for part in derived.split(","):
            if part.startswith(key + "="):
                return cast(part.split("=", 1)[1])
        return None

    for n, _us, derived in rows:
        if not n.startswith("comm/"):
            continue
        _, ds_name, method, kind = n.split("/")
        e = summ.setdefault(ds_name, {}).setdefault(method, {})
        if kind == "measured":
            e["measured_bytes_per_iter"] = _field(derived, "B/iter")
        elif kind == "model":
            e["model_bytes_per_iter"] = _field(derived, "B/iter")
            e["ratio"] = _field(derived, "ratio")
            e["r"] = _field(derived, "r")
        elif kind == "live":
            e["live_passes"] = _field(derived, "passes", int)
            e["live_ratio"] = _field(derived, "ratio")
    return summ
